"""§II-A inter-core register sharing: halo-exchange vs global-buffer
(all-gather) collective bytes on the 2D mesh — the Fig. 3(b) 3× memory-
read-reduction analogue.  Runs in a subprocess with 4 fake devices so the
benchmark process itself keeps a single-device view."""
from __future__ import annotations

import json
import subprocess
import sys
import os

from benchmarks.common import row

_CODE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, re, json
from repro.launch.mesh import make_pgm_mesh
from repro.pgm.networks import penguin_task
from repro.pgm.mesh_gibbs import make_mesh_gibbs_step, shard_mrf
mesh = make_pgm_mesh(4, 4)
mrf, _ = penguin_task(h=100, w=68)
key = jax.random.PRNGKey(0)
lab, u, pw, valid, _ = shard_mrf(mesh, mrf, n_chains=4, key=key)
def cbytes(step):
    txt = jax.jit(step).lower(key, lab, u, pw, valid).compile().as_text()
    tot = 0
    for line in txt.splitlines():
        for p in ("all-gather(", "all-gather-start", "collective-permute(",
                  "collective-permute-start"):
            if p in line and "=" in line:
                m = re.findall(r"(s32|u32|f32|pred)\\[([\\d,]*)\\]",
                               line.split("=",1)[1])
                if m:
                    dt, dims = m[0]
                    sz = {"s32":4,"u32":4,"f32":4,"pred":1}[dt]
                    for d in dims.split(","):
                        if d: sz *= int(d)
                    tot += sz
                break
    return tot
halo = cbytes(make_mesh_gibbs_step(mesh, comm="halo"))
ag = cbytes(make_mesh_gibbs_step(mesh, comm="allgather"))
print(json.dumps({"halo": halo, "allgather": ag}))
"""


def main(report=print):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), "src")
    p = subprocess.run([sys.executable, "-c", _CODE], env=env,
                       capture_output=True, text=True, timeout=900)
    line = [l for l in p.stdout.splitlines() if l.startswith("{")][-1]
    d = json.loads(line)
    ratio = d["allgather"] / max(d["halo"], 1)
    report(row("halo_exchange_bytes", d["halo"],
               f"allgather_bytes={d['allgather']};reduction={ratio:.1f}x;"
               f"paper_claim=3x_mem_reads"))


if __name__ == "__main__":
    main()
