"""Fig. 7 MRF workloads: Penguin segmentation + Art stereo.

CPU-measured MSample/s at reduced size (full 500×333 runs via
``launch.run_mcmc --scale 1``); the per-site sample cost is
size-independent so the rate extrapolates.  Accuracy vs synthetic ground
truth doubles as the correctness gate."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.pgm.gibbs import init_labels, mrf_gibbs
from repro.pgm.networks import art_task, penguin_task


def run(name, mrf, truth, chains=4, sweeps=10, report=print):
    h, w = mrf.shape
    labels = init_labels(jax.random.PRNGKey(0), mrf, chains)
    unary = jnp.asarray(mrf.unary)
    pairwise = jnp.asarray(mrf.pairwise)
    fn = jax.jit(lambda k, l: mrf_gibbs(k, l, unary, pairwise,
                                        n_sweeps=sweeps))
    dt = time_call(fn, jax.random.PRNGKey(1), labels, warmup=1, iters=3)
    out, stats = fn(jax.random.PRNGKey(1), labels)
    n_samples = chains * sweeps * h * w
    acc = float((np.asarray(out[0]) == truth).mean())
    bits = float(stats.bits_used) / n_samples
    report(row(name, dt / n_samples * 1e6,
               f"MSample/s={n_samples/dt/1e6:.2f};bits={bits:.2f};acc={acc:.3f}"))


def main(report=print):
    mrf, truth = penguin_task(h=100, w=66)   # 1/5 scale Penguin
    run("mrf_penguin_100x66_L2", mrf, truth, report=report)
    mrf, truth = art_task(h=72, w=96)        # 1/4 scale Art
    run("mrf_art_72x96_L16", mrf, truth, report=report)


if __name__ == "__main__":
    main()
