"""Fig. 7 MRF workloads: Penguin segmentation + Art stereo.

CPU-measured MSample/s at reduced size (full 500×333 runs via
``launch.run_mcmc --scale 1``); the per-site sample cost is
size-independent so the rate extrapolates.  Accuracy vs synthetic ground
truth doubles as the correctness gate.  ``run_masked`` adds the
evidence-clamped variants: direct clamped Gibbs MSample/s, and
masked-MRF queries/s through the posterior engine (interactive
segmentation served via ``repro.serve``)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.pgm.gibbs import clamp_labels, init_labels, mrf_gibbs
from repro.pgm.networks import art_task, penguin_task


def run(name, mrf, truth, chains=4, sweeps=10, report=print):
    h, w = mrf.shape
    labels = init_labels(jax.random.PRNGKey(0), mrf, chains)
    unary = jnp.asarray(mrf.unary)
    pairwise = jnp.asarray(mrf.pairwise)
    fn = jax.jit(lambda k, l: mrf_gibbs(k, l, unary, pairwise,
                                        n_sweeps=sweeps))
    dt = time_call(fn, jax.random.PRNGKey(1), labels, warmup=1, iters=3)
    out, stats = fn(jax.random.PRNGKey(1), labels)
    n_samples = chains * sweeps * h * w
    acc = float((np.asarray(out[0]) == truth).mean())
    bits = float(stats.bits_used) / n_samples
    report(row(name, dt / n_samples * 1e6,
               f"MSample/s={n_samples/dt/1e6:.2f};bits={bits:.2f};acc={acc:.3f}"))


def run_masked(name, mrf, truth, chains=4, sweeps=10, report=print):
    """Clamped-checkerboard throughput: ~10% of sites pinned to truth
    (a generous scribble), free-site MSample/s reported."""
    h, w = mrf.shape
    rng = np.random.default_rng(0)
    mask = rng.random((h, w)) < 0.1
    labels = clamp_labels(
        init_labels(jax.random.PRNGKey(0), mrf, chains), mask,
        np.where(mask, truth, 0))
    unary, pairwise = jnp.asarray(mrf.unary), jnp.asarray(mrf.pairwise)
    clamp = jnp.asarray(mask)
    fn = jax.jit(lambda k, l: mrf_gibbs(k, l, unary, pairwise,
                                        n_sweeps=sweeps, clamp=clamp))
    dt = time_call(fn, jax.random.PRNGKey(1), labels, warmup=1, iters=3)
    out, stats = fn(jax.random.PRNGKey(1), labels)
    n_samples = chains * sweeps * int((~mask).sum())
    acc = float((np.asarray(out[0]) == truth).mean())
    bits = float(stats.bits_used) / n_samples
    report(row(name, dt / n_samples * 1e6,
               f"MSample/s={n_samples/dt/1e6:.2f};bits={bits:.2f};"
               f"acc={acc:.3f};clamped={int(mask.sum())}"))


def run_masked_serve(name, h=24, w=24, n_queries=8, budget=1024,
                     report=print):
    """Masked-MRF qps through the posterior engine (warm plan cache) —
    the serving-facing number; the full cold/warm + identity treatment
    lives in ``benchmarks.bench_serve.run_mrf``."""
    from repro.serve.cli import synthetic_mrf_traffic
    from repro.serve.engine import PosteriorEngine

    mrf, _ = penguin_task(h=h, w=w)
    traffic = synthetic_mrf_traffic(
        mrf, "penguin", n_queries, 2, np.random.default_rng(0), budget)
    engine = PosteriorEngine({"penguin": mrf}, chains_per_query=8,
                             burn_in=32)
    engine.answer_batch(traffic)  # warm: compiles per mask pattern
    t0 = time.perf_counter()
    results = engine.answer_batch(traffic)
    dt = time.perf_counter() - t0
    conv = sum(r.converged for r in results)
    ess = sum(r.diagnostics.min_ess for r in results)
    report(row(name, dt / n_queries * 1e6,
               f"qps={n_queries/dt:.2f};ESS/s={ess/dt:.1f};"
               f"converged={conv}/{n_queries}"))


def main(report=print):
    mrf, truth = penguin_task(h=100, w=66)   # 1/5 scale Penguin
    run("mrf_penguin_100x66_L2", mrf, truth, report=report)
    run_masked("mrf_penguin_masked_100x66_L2", mrf, truth, report=report)
    mrf, truth = art_task(h=72, w=96)        # 1/4 scale Art
    run("mrf_art_72x96_L16", mrf, truth, report=report)
    run_masked_serve("mrf_masked_serve_24x24", report=report)


if __name__ == "__main__":
    main()
