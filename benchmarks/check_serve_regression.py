"""CI perf gate: compare a fresh BENCH_serve.json against the committed
baseline and fail on regression.

  PYTHONPATH=src:. python -m benchmarks.bench_serve --smoke --stream \
      --json BENCH_serve.json
  python benchmarks/check_serve_regression.py BENCH_serve.json \
      benchmarks/baselines/BENCH_serve.json --tolerance 0.30

Checks, per run matched by name against the baseline:

* warm queries/s must not drop more than ``--tolerance`` (relative) —
  warm throughput is pure sampling, the number the serving stack lives
  on; cold numbers are compile-dominated and too noisy to gate.
* the streaming section (when both reports carry one): queued queries/s
  under the same tolerance, queued-vs-synchronous speedup at least
  ``--min-stream-speedup``, and the queued-vs-``answer_batch`` identity
  bit must be True — a perf gate that lets the queue drift numerically
  would be enforcing the wrong thing.

The default tolerance is deliberately loose (30%) to absorb shared-CI
runner noise; the gate exists to catch step-function regressions (an
accidental recompile per query, a lost micro-batch), not single-digit
jitter.  The absolute queries/s comparison is still machine-relative to
wherever the baseline was generated — if the CI runner fleet changes
speed class, refresh the baseline from a CI-produced ``BENCH_serve``
artifact rather than a developer machine.  ``--update`` rewrites the
baseline from the current report instead of checking (commit the
result).
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


def _fail(failures: list[str]) -> None:
    for f in failures:
        print(f"FAIL: {f}")
    sys.exit(1)


def check(current: dict, baseline: dict, *, tolerance: float,
          min_stream_speedup: float) -> list[str]:
    failures = []
    floor = 1.0 - tolerance
    base_runs = {r["name"]: r for r in baseline.get("runs", [])}
    for run in current.get("runs", []):
        base = base_runs.get(run["name"])
        if base is None:
            continue
        cur_qps = run["warm"]["queries_per_s"]
        base_qps = base["warm"]["queries_per_s"]
        print(f"{run['name']}: warm {cur_qps:.2f} qps "
              f"(baseline {base_qps:.2f}, floor {base_qps * floor:.2f})")
        if cur_qps < base_qps * floor:
            failures.append(
                f"{run['name']}: warm queries/s regressed "
                f"{cur_qps:.2f} < {base_qps:.2f} * {floor:.2f}")
    missing = set(base_runs) - {r["name"] for r in current.get("runs", [])}
    if missing:
        failures.append(f"runs missing from current report: {sorted(missing)}")

    stream, base_stream = current.get("stream"), baseline.get("stream")
    if stream is not None:
        if not stream.get("identical", False):
            failures.append(
                "stream: queued results are not identical to answer_batch")
        speedup = stream.get("speedup", 0.0)
        print(f"stream: {stream['queries_per_s']:.2f} qps, "
              f"speedup {speedup:.2f}x vs sync "
              f"(floor {min_stream_speedup:.2f}x)")
        if speedup < min_stream_speedup:
            failures.append(
                f"stream: queued/sync speedup {speedup:.2f}x "
                f"< {min_stream_speedup:.2f}x")
        if base_stream is not None:
            cur, base = stream["queries_per_s"], base_stream["queries_per_s"]
            if cur < base * floor:
                failures.append(
                    f"stream: queued queries/s regressed "
                    f"{cur:.2f} < {base:.2f} * {floor:.2f}")
    elif base_stream is not None:
        failures.append("baseline has a stream section but current doesn't "
                        "(did the bench run without --stream?)")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="fresh BENCH_serve.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed relative throughput drop (default 0.30)")
    ap.add_argument("--min-stream-speedup", type=float, default=1.5,
                    help="required queued/sync queries/s ratio")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with the current report")
    args = ap.parse_args(argv)

    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return
    with open(args.current) as f:
        current = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures = check(current, baseline, tolerance=args.tolerance,
                     min_stream_speedup=args.min_stream_speedup)
    if failures:
        _fail(failures)
    print("perf gate: OK")


if __name__ == "__main__":
    main()
