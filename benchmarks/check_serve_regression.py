"""CI perf gate: compare a fresh BENCH_serve.json against the committed
baseline and fail on regression.

  PYTHONPATH=src:. python -m benchmarks.bench_serve --smoke --stream \
      --json BENCH_serve.json
  python benchmarks/check_serve_regression.py BENCH_serve.json \
      benchmarks/baselines/BENCH_serve.json --tolerance 0.30

Checks, per run matched by name against the baseline:

* warm queries/s must not drop more than ``--tolerance`` (relative) —
  warm throughput is pure sampling, the number the serving stack lives
  on; cold numbers are compile-dominated and too noisy to gate.  Covers
  both served families: Bayesian-network runs and masked-MRF runs.
* warm **ESS/s** (effective samples per second — the statistical-
  quality throughput the rank retirement rule optimizes for) under the
  same tolerance, shown in the same diff table.
* any run carrying an ``identical`` flag (the masked-MRF queued-vs-
  ``answer_batch`` check) must report True — a perf gate that lets the
  queue drift numerically would be enforcing the wrong thing.
* the streaming section (when both reports carry one): queued queries/s
  under the same tolerance, queued-vs-synchronous speedup at least
  ``--min-stream-speedup``, and the stream identity bit must be True.
* the ``map`` section (annealed MAP/MPE serving,
  ``docs/inference_modes.md``): warm queries/s under the same
  tolerance.  ESS/s is deliberately not compared — annealed chains
  don't mix, so effective-sample throughput is not a meaningful number
  for ``mode="map"``.
* the ``filtering`` section (temporal dynamic-BN filtering): the warm
  pass's per-slice plan-cache hit rate after slice 0 must be exactly
  100% and every post-slice-0 query must report ``warm_start`` — both
  are contract bits, not perf numbers — plus the cold/warm per-slice
  latency ratio at least ``--min-filtering-speedup`` (self-relative:
  warm slices skip burn-in, cold re-solves pay it) and warm slices/s
  against the baseline under the shared tolerance.
* the ``overload`` section (``bench_serve --overload``, when either
  report carries one): the served-vs-``answer_batch`` bitwise
  ``identical`` bit must be True (served over real HTTP, fresh server,
  same seed), the shed rate at 2x offered capacity must be at least
  ``--min-overload-shed`` (the front end must shed at the door — a
  zero shed rate under 2x load means every request is piling into the
  queue), hard transport ``errors`` must be zero (shedding is a *clean*
  429/503 + Retry-After, never a dropped connection), and served p99
  latency must stay within ``--max-overload-p99-ratio`` times the
  report's own mean service time (self-relative: bounded latency for
  the admitted subset is the whole point of shedding — a collapsing
  queue shows up here as p99 growing with the run length).  Capacity
  queries/s is additionally compared against the baseline under the
  shared tolerance.
* the ``sampler_pallas`` section (when the current report carries one):
  the fused-kernel-vs-XLA bitwise ``identical`` bit must be True on
  every platform — it is the whole contract of ``sampler="pallas"`` —
  and the fused/XLA warm-throughput ratio must meet
  ``--min-pallas-speedup`` *only* where the kernel actually compiles
  (``platform != "cpu"``; on CPU it runs through the Pallas interpreter
  and the ratio measures nothing).  Like the telemetry check this is
  self-relative — both backends were timed in the same process on
  identical traffic — so it needs no baseline entry.
* the ``telemetry_overhead`` section (when the current report carries
  one): enabled-recorder ESS/s must be within
  ``--telemetry-overhead-tolerance`` (default 5%) of the null-recorder
  ESS/s.  This check is **self-relative** — both sides were measured in
  the same bench process on identical traffic — so it needs no baseline
  entry and is immune to runner speed-class drift; it exists to catch a
  hot-path instrumentation regression (an args dict built without the
  ``enabled`` guard, an accidental diagnostics recompute).

Failures print one readable line each —
``FAIL metric=<name> baseline=<x> observed=<y> floor=<z> (tolerance N%)``
— and the gate exits 1.  **Exit 2** is reserved for a broken comparison
setup: a missing/unreadable baseline file, metrics present in the
current report with no baseline entry (so a freshly added benchmark can
never silently pass — commit a refreshed baseline via ``--update``
instead), or a **retirement-mode mismatch**: comparing a
``retirement="rank"`` report against a ``"legacy"`` baseline (or vice
versa) would diff incomparable sweeps-to-retirement regimes, so it is a
setup error, never a silent pass.

The default tolerance is deliberately loose (30%) to absorb shared-CI
runner noise; the gate exists to catch step-function regressions (an
accidental recompile per query, a lost micro-batch), not single-digit
jitter.  The absolute queries/s comparison is still machine-relative to
wherever the baseline was generated — if the CI runner fleet changes
speed class, refresh the baseline from a CI-produced ``BENCH_serve``
artifact rather than a developer machine.  ``--update`` rewrites the
baseline from the current report instead of checking (commit the
result).
"""
from __future__ import annotations

import argparse
import json
import shutil
import sys


class Failure:
    """One gate violation, printed as a metric/baseline/observed diff."""

    def __init__(self, metric: str, *, observed, baseline=None, floor=None,
                 tolerance=None, note: str = ""):
        self.metric = metric
        self.observed, self.baseline = observed, baseline
        self.floor, self.tolerance = floor, tolerance
        self.note = note

    def __str__(self) -> str:
        parts = [f"FAIL metric={self.metric}"]
        if self.baseline is not None:
            parts.append(f"baseline={self.baseline:.3f}")
        parts.append(f"observed={self.observed}")
        if self.floor is not None:
            parts.append(f"floor={self.floor:.3f}")
        if self.tolerance is not None:
            parts.append(f"(tolerance {self.tolerance:.0%})")
        if self.note:
            parts.append(f"— {self.note}")
        return " ".join(str(p) for p in parts)


def _qps_check(metric, cur, base, tolerance, unit="qps") -> Failure | None:
    floor = base * (1.0 - tolerance)
    print(f"{metric}: {cur:.2f} {unit} (baseline {base:.2f}, "
          f"floor {floor:.2f})")
    if cur < floor:
        return Failure(metric, observed=round(cur, 3), baseline=base,
                       floor=floor, tolerance=tolerance)
    return None


def _ess_check(metric, cur_section, base_section, tolerance,
               failures, setup) -> None:
    """Shared ESS/s comparison (warm runs and the stream section):
    regression under the same tolerance as qps, missing baseline entry
    = setup error — a freshly added ESS metric can never silently pass."""
    if "ess_per_s" not in cur_section:
        return
    if "ess_per_s" not in base_section:
        setup.append(Failure(
            metric, observed=round(cur_section["ess_per_s"], 3),
            note="no baseline ESS/s entry — refresh the baseline with "
                 "--update and commit it"))
        return
    f = _qps_check(metric, cur_section["ess_per_s"],
                   base_section["ess_per_s"], tolerance, unit="ESS/s")
    if f:
        failures.append(f)


def check(current: dict, baseline: dict, *, tolerance: float,
          min_stream_speedup: float,
          telemetry_overhead_tolerance: float = 0.05,
          min_pallas_speedup: float = 1.0,
          min_filtering_speedup: float = 1.2,
          min_overload_shed: float = 0.2,
          max_overload_p99_ratio: float = 50.0,
          ) -> tuple[list[Failure], list[Failure]]:
    """Returns ``(regressions, setup_errors)`` — setup errors (exit 2)
    are comparisons that *cannot* be made: current runs with no baseline
    entry, or reports produced under different retirement modes."""
    failures: list[Failure] = []
    setup: list[Failure] = []
    cur_mode = current.get("retirement")
    base_mode = baseline.get("retirement")
    if cur_mode != base_mode:
        setup.append(Failure(
            "retirement", observed=cur_mode,
            note=f"baseline was produced under retirement="
                 f"{base_mode!r} — sweeps-to-retirement regimes are "
                 f"incomparable; refresh the baseline with --update "
                 f"and commit it"))
    base_runs = {r["name"]: r for r in baseline.get("runs", [])}
    for run in current.get("runs", []):
        base = base_runs.get(run["name"])
        if base is None:
            setup.append(Failure(
                f"{run['name']}.warm.queries_per_s",
                observed=round(run["warm"]["queries_per_s"], 3),
                note="no baseline entry — new metric? refresh the "
                     "baseline with --update and commit it"))
            continue
        f = _qps_check(f"{run['name']}.warm.queries_per_s",
                       run["warm"]["queries_per_s"],
                       base["warm"]["queries_per_s"], tolerance)
        if f:
            failures.append(f)
        # ESS/s: same diff table, same tolerance — statistical-quality
        # throughput regressions (a retirement rule gone lax shows up
        # here before it shows up in qps)
        _ess_check(f"{run['name']}.warm.ess_per_s", run.get("warm", {}),
                   base.get("warm", {}), tolerance, failures, setup)
        if "identical" in run and not run["identical"]:
            failures.append(Failure(
                f"{run['name']}.identical", observed=False,
                note="queued results are not identical to answer_batch"))
    missing = set(base_runs) - {r["name"] for r in current.get("runs", [])}
    for name in sorted(missing):
        failures.append(Failure(
            f"{name}.warm.queries_per_s", observed="absent",
            note="run missing from current report"))

    stream, base_stream = current.get("stream"), baseline.get("stream")
    if stream is not None:
        if not stream.get("identical", False):
            failures.append(Failure(
                "stream.identical", observed=False,
                note="queued results are not identical to answer_batch"))
        speedup = stream.get("speedup", 0.0)
        print(f"stream: {stream['queries_per_s']:.2f} qps, "
              f"speedup {speedup:.2f}x vs sync "
              f"(floor {min_stream_speedup:.2f}x)")
        if speedup < min_stream_speedup:
            failures.append(Failure(
                "stream.speedup", observed=round(speedup, 3),
                floor=min_stream_speedup,
                note="queued/sync throughput ratio below floor"))
        if base_stream is not None:
            f = _qps_check("stream.queries_per_s",
                           stream["queries_per_s"],
                           base_stream["queries_per_s"], tolerance)
            if f:
                failures.append(f)
            _ess_check("stream.ess_per_s", stream, base_stream,
                       tolerance, failures, setup)
        else:
            setup.append(Failure(
                "stream.queries_per_s",
                observed=round(stream["queries_per_s"], 3),
                note="no baseline stream section — refresh the baseline "
                     "with --update and commit it"))
    elif base_stream is not None:
        failures.append(Failure(
            "stream", observed="absent",
            note="baseline has a stream section but current doesn't "
                 "(did the bench run without --stream?)"))

    # MAP section (annealed MAP/MPE qps — docs/inference_modes.md):
    # warm queries/s against the baseline under the shared tolerance.
    # ESS/s is deliberately absent (annealed chains don't mix), and the
    # cold-vs-warm assignment agreement is informational only — the two
    # passes consume different key-stream positions.
    map_sec, base_map = current.get("map"), baseline.get("map")
    if map_sec is not None:
        if base_map is not None:
            f = _qps_check("map.warm.queries_per_s",
                           map_sec["warm"]["queries_per_s"],
                           base_map["warm"]["queries_per_s"], tolerance)
            if f:
                failures.append(f)
        else:
            setup.append(Failure(
                "map.warm.queries_per_s",
                observed=round(map_sec["warm"]["queries_per_s"], 3),
                note="no baseline map section — refresh the baseline "
                     "with --update and commit it"))
    elif base_map is not None:
        failures.append(Failure(
            "map", observed="absent",
            note="baseline has a map section but current doesn't"))

    # temporal-filtering section: two self-relative contract bits (the
    # warm pass's per-slice plan-cache hit rate must be 100% after
    # slice 0, and every post-slice-0 query must have warm-started)
    # plus the cold/warm per-slice latency ratio against its floor and
    # the warm per-slice throughput against the baseline.
    filt, base_filt = current.get("filtering"), baseline.get("filtering")
    if filt is not None:
        hit = filt.get("warm_hit_rate_after_slice0", 0.0)
        speedup = filt.get("speedup", 0.0)
        print(f"filtering: warm {filt['warm_slice_ms']:.1f} ms/slice vs "
              f"cold {filt['cold_slice_ms']:.1f} ms/slice — "
              f"{speedup:.2f}x (floor {min_filtering_speedup:.2f}x), "
              f"post-slice-0 hit rate {hit:.2f}, warm-started "
              f"{filt['warm_started']}/{filt['expected_warm']}")
        if hit < 1.0:
            failures.append(Failure(
                "filtering.warm_hit_rate_after_slice0",
                observed=round(hit, 3), floor=1.0,
                note="a post-slice-0 slice missed the plan cache — "
                     "slice traffic should reuse its stream's plan"))
        if filt["warm_started"] != filt["expected_warm"]:
            failures.append(Failure(
                "filtering.warm_started", observed=filt["warm_started"],
                floor=float(filt["expected_warm"]),
                note="a post-slice-0 query did not warm-start from its "
                     "stream's retained chains"))
        if speedup < min_filtering_speedup:
            failures.append(Failure(
                "filtering.speedup", observed=round(speedup, 3),
                floor=min_filtering_speedup,
                note="warm-start per-slice latency advantage below "
                     "floor — is burn-in being skipped?"))
        if base_filt is not None:
            f = _qps_check("filtering.slices_per_s_warm",
                           filt["slices_per_s_warm"],
                           base_filt["slices_per_s_warm"], tolerance,
                           unit="slices/s")
            if f:
                failures.append(f)
        else:
            setup.append(Failure(
                "filtering.slices_per_s_warm",
                observed=round(filt["slices_per_s_warm"], 3),
                note="no baseline filtering section — refresh the "
                     "baseline with --update and commit it"))
    elif base_filt is not None:
        failures.append(Failure(
            "filtering", observed="absent",
            note="baseline has a filtering section but current doesn't"))

    # overload section (bench_serve --overload): SLO serving under 2x
    # offered load over real HTTP.  Identity and clean shedding are
    # contract bits; the p99 bound is self-relative (vs this report's
    # own mean service time); capacity qps diffs against the baseline.
    ov, base_ov = current.get("overload"), baseline.get("overload")
    if ov is not None:
        p99_cap = max_overload_p99_ratio * ov["mean_service_ms"]
        print(f"overload: capacity {ov['capacity_qps']:.2f} qps, offered "
              f"{ov['offered_qps']:.2f} qps, shed rate "
              f"{ov['shed_rate']:.2f} (floor {min_overload_shed:.2f}), "
              f"p50 {ov['p50_ms']:.1f} ms, p99 {ov['p99_ms']:.1f} ms "
              f"(cap {p99_cap:.1f} ms), errors {ov['errors']}")
        if not ov.get("identical", False):
            failures.append(Failure(
                "overload.identical", observed=False,
                note="HTTP-served marginals are not bitwise identical "
                     "to in-process answer_batch on the same seed"))
        if ov["shed_rate"] < min_overload_shed:
            failures.append(Failure(
                "overload.shed_rate", observed=round(ov["shed_rate"], 3),
                floor=min_overload_shed,
                note="2x offered load is not being shed at the front "
                     "door — it is piling into the queue instead"))
        if ov["errors"]:
            failures.append(Failure(
                "overload.errors", observed=ov["errors"], floor=0.0,
                note="overload must shed with clean 429/503 responses, "
                     "never dropped connections or transport errors"))
        if not ov["p99_ms"] <= p99_cap:   # NaN (nothing served) fails too
            failures.append(Failure(
                "overload.p99_ms", observed=round(ov["p99_ms"], 1),
                floor=p99_cap,
                note="served p99 blew past the bounded-latency cap — "
                     "queue collapse instead of admission shedding"))
        if base_ov is not None:
            f = _qps_check("overload.capacity_qps", ov["capacity_qps"],
                           base_ov["capacity_qps"], tolerance)
            if f:
                failures.append(f)
        else:
            setup.append(Failure(
                "overload.capacity_qps",
                observed=round(ov["capacity_qps"], 3),
                note="no baseline overload section — refresh the "
                     "baseline with --update and commit it"))
    elif base_ov is not None:
        failures.append(Failure(
            "overload", observed="absent",
            note="baseline has an overload section but current doesn't "
                 "(did the bench run without --overload?)"))

    # telemetry overhead: self-relative (null vs enabled recorder were
    # measured in the same process on identical traffic), so no baseline
    # entry is consulted — the floor is the current report's own null
    # run.  The gated number is the report's ``ratio``: the min-time
    # ratio over interleaved passes doing bitwise-identical work, i.e.
    # the ESS/s ratio with the (identical) ESS cancelled exactly.
    overhead = current.get("telemetry_overhead")
    if overhead is not None:
        ratio = overhead.get("ratio")
        if ratio is None:
            ratio = (overhead["ess_per_s_enabled"]
                     / max(overhead["ess_per_s_null"], 1e-12))
        floor = 1.0 - telemetry_overhead_tolerance
        print(f"telemetry_overhead: enabled/null throughput ratio "
              f"{ratio:.3f} (floor {floor:.3f}; "
              f"{overhead['ess_per_s_enabled']:.1f} vs "
              f"{overhead['ess_per_s_null']:.1f} ESS/s)")
        if ratio < floor:
            failures.append(Failure(
                "telemetry_overhead.ratio",
                observed=round(ratio, 3), floor=floor,
                tolerance=telemetry_overhead_tolerance,
                note="live recorder costs more than the overhead budget "
                     "— check the telemetry.enabled guards on hot paths"))

    # sampler backends: the bitwise-identity bit is unconditional (it is
    # the sampler="pallas" contract); the fused/XLA speedup floor only
    # applies where the kernel compiles — on CPU it runs interpreted and
    # the ratio is a correctness-plumbing number, not a perf one.
    sp = current.get("sampler_pallas")
    if sp is not None:
        if not sp.get("identical", False):
            failures.append(Failure(
                "sampler_pallas.identical", observed=False,
                note="fused Pallas sampler results differ from the XLA "
                     "path — the bitwise contract is broken"))
        speedup = sp.get("speedup", 0.0)
        platform = sp.get("platform", "cpu")
        gated = platform != "cpu"
        print(f"sampler_pallas: identical={sp.get('identical')}, "
              f"fused/xla {speedup:.2f}x on {platform} "
              + (f"(floor {min_pallas_speedup:.2f}x)" if gated
                 else "(interpreted — speedup not gated)"))
        if gated and speedup < min_pallas_speedup:
            failures.append(Failure(
                "sampler_pallas.speedup", observed=round(speedup, 3),
                floor=min_pallas_speedup,
                note="fused kernel slower than the two-stage XLA path "
                     "on a compiled backend"))
    return failures, setup


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="fresh BENCH_serve.json")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--tolerance", type=float, default=0.30,
                    help="allowed relative throughput drop (default 0.30)")
    ap.add_argument("--min-stream-speedup", type=float, default=1.5,
                    help="required queued/sync queries/s ratio")
    ap.add_argument("--telemetry-overhead-tolerance", type=float,
                    default=0.05,
                    help="allowed relative ESS/s cost of the live "
                         "telemetry recorder vs the null recorder "
                         "(self-relative; default 0.05)")
    ap.add_argument("--min-pallas-speedup", type=float, default=1.0,
                    help="required fused-pallas/xla warm-throughput "
                         "ratio on compiled (non-CPU) backends; the "
                         "bitwise identity bit is gated on every "
                         "platform regardless")
    ap.add_argument("--min-filtering-speedup", type=float, default=1.2,
                    help="required cold/warm per-slice latency ratio for "
                         "the temporal-filtering section (warm slices "
                         "skip burn-in; self-relative)")
    ap.add_argument("--min-overload-shed", type=float, default=0.2,
                    help="required shed rate under 2x offered capacity "
                         "in the overload section (shed at the front "
                         "door, not queue collapse)")
    ap.add_argument("--max-overload-p99-ratio", type=float, default=50.0,
                    help="served p99 latency cap for the overload "
                         "section, as a multiple of the report's own "
                         "mean service time (self-relative)")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the baseline with the current report")
    args = ap.parse_args(argv)

    if args.update:
        shutil.copyfile(args.current, args.baseline)
        print(f"baseline updated: {args.baseline}")
        return
    with open(args.current) as f:
        current = json.load(f)
    try:
        with open(args.baseline) as f:
            baseline = json.load(f)
    except (OSError, ValueError) as exc:
        print(f"FAIL metric=baseline observed=unreadable — {args.baseline}: "
              f"{exc} (run with --update to create it, then commit)")
        sys.exit(2)
    failures, setup = check(
        current, baseline, tolerance=args.tolerance,
        min_stream_speedup=args.min_stream_speedup,
        telemetry_overhead_tolerance=args.telemetry_overhead_tolerance,
        min_pallas_speedup=args.min_pallas_speedup,
        min_filtering_speedup=args.min_filtering_speedup,
        min_overload_shed=args.min_overload_shed,
        max_overload_p99_ratio=args.max_overload_p99_ratio)
    for f in failures + setup:
        print(f)
    if setup:
        sys.exit(2)
    if failures:
        sys.exit(1)
    print("perf gate: OK")


if __name__ == "__main__":
    main()
