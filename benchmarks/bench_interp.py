"""§II-B IU claim: LUT interpolation vs transcendental evaluation
(paper: 9× vs a memory-based LUT; single-cycle vs multi-cycle exp).

We compare the PWL interpolation against jnp.exp/log on CPU wall time
and report max abs error (the accuracy side of the trade)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core import exp_table, iu_log


def main(report=print):
    x = jax.random.uniform(jax.random.PRNGKey(0), (4096, 1024),
                           minval=-16.0, maxval=0.0)
    t = exp_table()
    iu = jax.jit(t.__call__)
    ref = jax.jit(jnp.exp)
    t_iu = time_call(iu, x)
    t_ref = time_call(ref, x)
    err = float(jnp.max(jnp.abs(iu(x) - jnp.exp(x))))
    report(row("iu_exp", t_iu / x.size * 1e6,
               f"exact_exp_us={t_ref / x.size * 1e6:.4f};"
               f"speedup={t_ref / t_iu:.2f}x;max_err={err:.2e}"))

    xp = jax.random.uniform(jax.random.PRNGKey(1), (4096, 1024),
                            minval=1e-6, maxval=100.0)
    ilog = jax.jit(iu_log)
    rlog = jax.jit(jnp.log)
    t_il = time_call(ilog, xp)
    t_rl = time_call(rlog, xp)
    err = float(jnp.max(jnp.abs(ilog(xp) - jnp.log(xp))))
    report(row("iu_log", t_il / xp.size * 1e6,
               f"exact_log_us={t_rl / xp.size * 1e6:.4f};"
               f"speedup={t_rl / t_il:.2f}x;max_err={err:.2e}"))


if __name__ == "__main__":
    main()
