"""Fig. 6 analogue: sampler performance vs distribution entropy.

The paper's Schmoo sweeps voltage/frequency while sampling distributions
of different entropies; without silicon we sweep the entropy axis and
report measured samples/s (CPU) + random-bits/sample (HW-independent),
plus the modeled TPU-v5e throughput from the roofline terms.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.core import entropy_bits, ky_sample, quantize_probs


def sweep(batch: int = 65536, n: int = 16, k: int = 12):
    out = []
    sampler = jax.jit(lambda key, w: ky_sample(key, w))
    for alpha in (0.02, 0.1, 0.5, 2.0, 50.0):
        p = jax.random.dirichlet(jax.random.PRNGKey(int(alpha * 100)),
                                 jnp.full((n,), alpha), (batch,))
        w = quantize_probs(p, k)
        key = jax.random.PRNGKey(0)
        dt = time_call(sampler, key, w)
        res = sampler(key, w)
        h = float(jnp.mean(entropy_bits(p)))
        bits = float(res.bits_used.mean())
        msps = batch / dt / 1e6
        out.append((h, bits, msps, dt))
    return out


def main(report=print):
    for h, bits, msps, dt in sweep():
        report(row(f"schmoo_H{h:.2f}", dt * 1e6,
                   f"bits/sample={bits:.2f};MSample/s={msps:.2f};H+2={h+2:.2f}"))


if __name__ == "__main__":
    main()
